"""The six mgdlint rules — each encodes a failure class this repo has
actually hit (or engineered against) at the hardware/host boundary:

* MGD001 host-callback purity       — the PR 2 CPU-client deadlock
* MGD002 counter-keyed randomness   — the PR 4/6 bit-exact-resume law
* MGD003 timeout discipline         — the PR 6 hung-instrument hang
* MGD004 traced-step shape stability— fixed-shape masking (PR 6)
* MGD005 lock discipline            — host-side shared state (PR 7)
* MGD006 fence-before-sync          — pipelined-farm boundaries (PR 7)

Every rule is path-scoped on the repo layout (``src/repro/...``) and
carries good/bad fixture snippets consumed by both ``--self-test`` and
``tests/test_mgdlint.py``.
"""
from __future__ import annotations

import ast
from typing import List, Optional, Set

from .registry import Finding, Rule, register
from .walker import (SourceFile, call_has_kwarg, call_positional_count,
                     dotted_name)

HARDWARE = "src/repro/hardware/"

# ---------------------------------------------------------------------------
# MGD001 — host-callback purity
# ---------------------------------------------------------------------------

#: jax submodules that never dispatch XLA work — pytree bookkeeping is
#: legitimate host-side (``jax.tree_util.tree_map`` over numpy leaves).
_JAX_HOST_SAFE = ("jax.tree_util", "jax.tree")

#: Modules under hardware/ that are host-side IN FULL (their module
#: docstrings promise numpy-purity): device simulators, the fault
#: engine, and every execution backend (whose code runs on worker
#: threads/processes, far from the traced program).
_HOST_PURE_MODULES = ("src/repro/hardware/devices.py",
                      "src/repro/hardware/faults.py")
_HOST_PURE_DIRS = ("src/repro/hardware/backend/",)


@register
class HostCallbackPurity(Rule):
    """Any function reachable from an ``io_callback`` registration (or
    named ``_host_*``) must not touch ``jax``/``jnp``: a host callback
    that dispatches JAX ops re-enters the CPU client that is blocked
    waiting on the callback — the PR 2 deadlock."""

    code = "MGD001"
    title = "host-callback purity"
    rationale = (
        "JAX ops inside an ordered io_callback deadlock the CPU client "
        "(two threads feeding one runtime). Host-side device code must "
        "be numpy/stdlib-pure; only jax.tree_util/jax.tree pytree "
        "bookkeeping is allowed.")
    fixture_path = "src/repro/hardware/fixture_mod.py"
    fixture_bad = """\
import jax
import jax.numpy as jnp
import numpy as np


class P:
    def _host_read(self, params, batch, step):
        return jnp.mean(params["w"])  # dispatches XLA inside the callback

    def read(self, params, batch, step):
        return io_callback(self._host_read, None, params, batch, step)
"""
    fixture_good = """\
import jax
import jax.numpy as jnp
import numpy as np


class P:
    def _host_read(self, params, batch, step):
        arrs = jax.tree_util.tree_map(np.asarray, params)
        return np.float32(np.mean(arrs["w"]))

    def read(self, params, batch, step):
        out = io_callback(self._host_read, None, params, batch, step)
        return jnp.asarray(out)
"""

    def applies(self, rel: str) -> bool:
        return rel.startswith(HARDWARE)

    def check(self, source: SourceFile) -> List[Finding]:
        whole_module = (source.rel in _HOST_PURE_MODULES
                        or any(source.rel.startswith(d)
                               for d in _HOST_PURE_DIRS))
        if whole_module:
            host_functions = None      # everything is host-side
        else:
            host_functions = self._reachable_host_functions(source)
            if not host_functions:
                return []
        findings = []
        for node in ast.walk(source.tree):
            if not isinstance(node, (ast.Name, ast.Attribute)):
                continue
            parent = source.parent(node)
            if isinstance(parent, ast.Attribute):
                continue               # only report the full chain once
            resolved = source.resolve(node)
            if resolved is None or not (
                    resolved == "jax" or resolved.startswith("jax.")):
                continue
            if any(resolved == safe or resolved.startswith(safe + ".")
                   for safe in _JAX_HOST_SAFE):
                continue
            if self._in_import(source, node):
                continue
            if host_functions is not None:
                fn = source.enclosing_function(node)
                if fn is None or fn not in host_functions:
                    continue
            where = ("host-side module" if host_functions is None
                     else "function reachable from an io_callback")
            findings.append(self.finding(
                source, node,
                f"`{dotted_name(node)}` (= {resolved}) used in a "
                f"{where} — JAX ops inside a host callback can deadlock "
                f"the CPU client; keep host code numpy-pure "
                f"(jax.tree_util bookkeeping is exempt)"))
        return findings

    @staticmethod
    def _in_import(source: SourceFile, node: ast.AST) -> bool:
        return any(isinstance(a, (ast.Import, ast.ImportFrom))
                   for a in source.ancestors(node))

    def _reachable_host_functions(self, source: SourceFile) \
            -> Set[ast.FunctionDef]:
        """Functions reachable from io_callback registrations and
        ``_host_*`` entry points, by bare-name reference within the
        file (methods and module functions alike — an intentional
        over-approximation; precision comes from the waiver syntax)."""
        by_name = {}
        for node in ast.walk(source.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                by_name.setdefault(node.name, []).append(node)
        entries: List[ast.FunctionDef] = []
        for name, fns in by_name.items():
            if name.startswith("_host"):
                entries.extend(fns)
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            name = dotted_name(node.func) or ""
            if name.split(".")[-1] not in ("io_callback", "_io_callback",
                                           "pure_callback"):
                continue
            if node.args:
                target = dotted_name(node.args[0])
                if target:
                    entries.extend(by_name.get(target.split(".")[-1], []))
        reachable: Set[ast.FunctionDef] = set()
        work = list(entries)
        while work:
            fn = work.pop()
            if fn in reachable:
                continue
            reachable.add(fn)
            for node in ast.walk(fn):
                if isinstance(node, (ast.Name, ast.Attribute)):
                    name = dotted_name(node)
                    if name is None:
                        continue
                    for callee in by_name.get(name.split(".")[-1], ()):
                        if callee not in reachable:
                            work.append(callee)
        return reachable


# ---------------------------------------------------------------------------
# MGD002 — counter-keyed randomness
# ---------------------------------------------------------------------------

#: Seeded-generator constructors are the sanctioned numpy API — what the
#: devices use (``np.random.default_rng((seed, step, tag))``).  Module-
#: level draws (``np.random.normal``) consume hidden global state.
_NP_RANDOM_OK = {"default_rng", "Generator", "SeedSequence", "BitGenerator",
                 "PCG64", "PCG64DXSM", "Philox", "SFC64", "MT19937"}
_STDLIB_RANDOM_OK = {"Random"}
_CLOCK_CALLS = {"time.time", "time.time_ns", "time.monotonic",
                "time.monotonic_ns", "time.perf_counter",
                "time.perf_counter_ns", "datetime.datetime.now",
                "datetime.now", "uuid.uuid4", "os.urandom"}
_SEEDISH = ("seed", "rng", "key", "random")


@register
class CounterKeyedRandomness(Rule):
    """No global-state RNG anywhere in ``src/repro/``: every noise draw
    must derive from an explicit ``(seed, step, tag)``-style key so
    retries, backends and resume replay bit-identical streams."""

    code = "MGD002"
    title = "counter-keyed randomness"
    rationale = (
        "Retry-heal bit-exactness, backend interchangeability and "
        "checkpoint resume (PRs 4-7) all assume noise is a pure "
        "function of (seed, step, tag, attempt). Global-state RNGs "
        "(np.random module calls, stdlib random, wall-clock seeds) "
        "make the stream depend on call COUNT and schedule.")
    fixture_path = "src/repro/core/fixture_mod.py"
    fixture_bad = """\
import numpy as np


def probe_noise(shape, step):
    return np.random.normal(0.0, 1.0, shape)  # hidden global stream
"""
    fixture_good = """\
import numpy as np


def probe_noise(shape, seed, step, tag):
    rng = np.random.default_rng((seed, step, tag))
    return rng.normal(0.0, 1.0, shape)
"""

    def applies(self, rel: str) -> bool:
        return rel.startswith("src/repro/")

    def check(self, source: SourceFile) -> List[Finding]:
        findings = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            resolved = source.resolve(node.func) or ""
            short = resolved.rsplit(".", 1)[-1]
            if resolved.startswith("numpy.random.") \
                    and short not in _NP_RANDOM_OK:
                findings.append(self.finding(
                    source, node,
                    f"global-state RNG call `{dotted_name(node.func)}` — "
                    f"draw from an explicit counter-keyed generator "
                    f"(np.random.default_rng((seed, step, tag))) instead"))
            elif (resolved.startswith("random.")
                    and resolved.count(".") == 1
                    and short not in _STDLIB_RANDOM_OK):
                findings.append(self.finding(
                    source, node,
                    f"stdlib global RNG call `{dotted_name(node.func)}` — "
                    f"stdlib random shares one hidden global stream; use "
                    f"a counter-keyed np.random.default_rng"))
            elif any(s in (dotted_name(node.func) or "").lower()
                     for s in _SEEDISH):
                clock = self._clock_arg(source, node)
                if clock is not None:
                    findings.append(self.finding(
                        source, node,
                        f"wall-clock value `{clock}` used to seed "
                        f"`{dotted_name(node.func)}` — seeds must be "
                        f"explicit so two runs replay the same stream"))
        return findings

    @staticmethod
    def _clock_arg(source: SourceFile, call: ast.Call) -> Optional[str]:
        for arg in list(call.args) + [k.value for k in call.keywords]:
            for sub in ast.walk(arg):
                if isinstance(sub, ast.Call):
                    resolved = source.resolve(sub.func) or ""
                    if resolved in _CLOCK_CALLS:
                        return dotted_name(sub.func)
        return None


# ---------------------------------------------------------------------------
# MGD003 — timeout discipline
# ---------------------------------------------------------------------------


@register
class TimeoutDiscipline(Rule):
    """Every blocking gather in ``hardware/`` carries an explicit
    timeout: ``Future.result()``, ``wait()``, zero-arg ``Queue.get()``,
    bare ``.join()``, bare ``.acquire()`` — one hung instrument must
    stall one bounded attempt, not freeze training (the PR 6 class,
    previously guarded by a regex that missed multi-line calls)."""

    code = "MGD003"
    title = "timeout discipline"
    rationale = (
        "A gather with no timeout inside an ordered io_callback turns a "
        "hung instrument into an un-interruptible training freeze "
        "(Ctrl-C barely works). Timeouts make a hang cost one bounded "
        "attempt and surface as a diagnosable ChipFaultError.")
    fixture_path = "src/repro/hardware/fixture_mod.py"
    fixture_bad = """\
def gather(futures):
    return [f.result() for f in futures]  # hung chip == frozen trainer
"""
    fixture_good = """\
TIMEOUT_S = 120.0


def gather(futures):
    return [f.result(timeout=TIMEOUT_S) for f in futures]
"""

    def applies(self, rel: str) -> bool:
        return rel.startswith(HARDWARE)

    def check(self, source: SourceFile) -> List[Finding]:
        findings = []
        for node in ast.walk(source.tree):
            if not isinstance(node, ast.Call):
                continue
            msg = self._violation(source, node)
            if msg:
                findings.append(self.finding(source, node, msg))
        return findings

    @staticmethod
    def _violation(source: SourceFile, call: ast.Call) -> Optional[str]:
        has_timeout = (call_has_kwarg(call, "timeout")
                       or call_positional_count(call) > 0)
        if isinstance(call.func, ast.Attribute):
            method = call.func.attr
            if method == "result" and not has_timeout:
                return ("`.result()` without a timeout — a hung chip "
                        "blocks the ordered callback forever; pass "
                        "timeout= (see faults.DEFAULT_TIMEOUT_S)")
            if method == "wait" and not has_timeout:
                return ("`.wait()` without a timeout — bound every "
                        "blocking wait so a dead worker cannot hang "
                        "teardown or training")
            if method == "get" and not call.args and not call.keywords:
                return ("zero-argument `.get()` — a blocking queue read "
                        "with no deadline; pass timeout= (or waive with "
                        "a reason if a shutdown sentinel guarantees "
                        "wakeup)")
            if method == "join" and not call.args \
                    and not call_has_kwarg(call, "timeout"):
                return ("bare `.join()` — joining a hung worker hangs "
                        "the caller; pass a bounded timeout")
            if method == "acquire" and not call.args and not call.keywords:
                return ("blocking `.acquire()` without a timeout — "
                        "prefer `with lock:` for scoped holds or pass "
                        "timeout=")
            return None
        resolved = source.resolve(call.func) or ""
        if resolved in ("concurrent.futures.wait", "futures.wait") \
                and not call_has_kwarg(call, "timeout") \
                and call_positional_count(call) < 2:
            return ("`concurrent.futures.wait()` without a timeout — "
                    "bound the gather")
        return None


# ---------------------------------------------------------------------------
# MGD004 — traced-step shape/control stability
# ---------------------------------------------------------------------------

#: Attribute reads that are STATIC under tracing (metadata, not values)
#: — accessing them on a traced array never concretizes it.
_STATIC_ATTRS = {"dtype", "shape", "ndim", "size", "sharding"}
_COERCIONS = {"float", "int", "bool", "complex"}


class _Taint:
    """Tiny forward taint pass over one traced inner function: parameters
    are traced; assignments propagate; ``.dtype``/``.shape``-style
    metadata reads launder the taint (static at trace time)."""

    def __init__(self, fn: ast.FunctionDef):
        args = fn.args
        self.tainted: Set[str] = {
            a.arg for a in (args.posonlyargs + args.args + args.kwonlyargs)}
        for a in (args.vararg, args.kwarg):
            if a is not None:
                self.tainted.add(a.arg)
        self.tainted.discard("self")

    def expr(self, node: ast.AST) -> bool:
        """Whether the VALUE of this expression is traced-tainted."""
        if isinstance(node, ast.Name):
            return node.id in self.tainted
        if isinstance(node, ast.Attribute):
            if node.attr in _STATIC_ATTRS:
                return False
            return self.expr(node.value)
        if isinstance(node, ast.Call):
            return any(self.expr(a) for a in node.args) or \
                any(self.expr(k.value) for k in node.keywords)
        if isinstance(node, (ast.BinOp,)):
            return self.expr(node.left) or self.expr(node.right)
        if isinstance(node, ast.UnaryOp):
            return self.expr(node.operand)
        if isinstance(node, ast.BoolOp):
            return any(self.expr(v) for v in node.values)
        if isinstance(node, ast.Compare):
            return self.expr(node.left) or \
                any(self.expr(c) for c in node.comparators)
        if isinstance(node, ast.Subscript):
            return self.expr(node.value)
        if isinstance(node, (ast.Tuple, ast.List, ast.Set)):
            return any(self.expr(e) for e in node.elts)
        if isinstance(node, ast.IfExp):
            return (self.expr(node.test) or self.expr(node.body)
                    or self.expr(node.orelse))
        if isinstance(node, ast.Starred):
            return self.expr(node.value)
        if isinstance(node, (ast.GeneratorExp, ast.ListComp, ast.SetComp)):
            for gen in node.generators:
                if self.expr(gen.iter):
                    self._bind_target(gen.target)
            return self.expr(node.elt)
        return False

    def _bind_target(self, target: ast.AST) -> None:
        for sub in ast.walk(target):
            if isinstance(sub, ast.Name):
                self.tainted.add(sub.id)

    def assign(self, node: ast.Assign) -> None:
        if self.expr(node.value):
            for t in node.targets:
                self._bind_target(t)

    def aug_or_ann(self, target: ast.AST, value: Optional[ast.AST]) -> None:
        if value is not None and self.expr(value):
            self._bind_target(target)


@register
class TracedStepStability(Rule):
    """Inside a step builder's traced inner functions (``core/``,
    ``api/``), no Python coercions (``float()``/``int()``/``bool()``/
    ``.item()``) of traced values and no Python ``if``/``while`` on
    them: the traced program must stay static-shape and cond-free so
    per-chip masking keeps fixed shapes and external plants stay legal
    (ordered callbacks cannot sit in cond branches)."""

    code = "MGD004"
    title = "traced-step shape stability"
    rationale = (
        "The fault-tolerant farm hands the traced step FIXED-shape "
        "(costs, valid) pairs and masks in-trace; a float()/.item() "
        "coercion or Python branch on a traced value either crashes "
        "under jit or silently re-traces per value, and ordered "
        "io_callbacks are illegal inside cond branches.")
    fixture_path = "src/repro/core/fixture_mod.py"
    fixture_bad = """\
import jax.numpy as jnp


def build_step(cfg):
    def step(params, state, batch):
        c = jnp.mean(params["w"] * batch)
        if c > 0:  # Python branch on a traced value
            c = c * 2.0
        return float(c), state
    return step
"""
    fixture_good = """\
import jax.numpy as jnp


def build_step(cfg):
    scale = float(cfg.eta)  # builder-level config math is static

    def step(params, state, batch):
        c = jnp.mean(params["w"] * batch)
        c = jnp.where(c > 0, c * 2.0, c)
        return c * scale, state
    return step
"""

    def applies(self, rel: str) -> bool:
        return (rel.startswith("src/repro/core/")
                or rel.startswith("src/repro/api/")
                or rel.startswith("src/repro/distributed/"))

    def check(self, source: SourceFile) -> List[Finding]:
        findings = []
        for builder in ast.walk(source.tree):
            if not isinstance(builder, ast.FunctionDef):
                continue
            if not builder.name.startswith(("build_", "make_")):
                continue
            for inner in ast.walk(builder):
                if inner is builder or not isinstance(
                        inner, ast.FunctionDef):
                    continue
                findings.extend(self._check_inner(source, inner))
        return findings

    def _check_inner(self, source: SourceFile,
                     fn: ast.FunctionDef) -> List[Finding]:
        taint = _Taint(fn)
        findings = []
        own = [n for n in ast.walk(fn)
               if source.enclosing_function(n) is fn or n is fn]
        for node in own:
            if isinstance(node, ast.Assign):
                taint.assign(node)
            elif isinstance(node, ast.AugAssign):
                taint.aug_or_ann(node.target, node.value)
            elif isinstance(node, ast.AnnAssign):
                taint.aug_or_ann(node.target, node.value)
            elif isinstance(node, ast.For):
                if taint.expr(node.iter):
                    taint._bind_target(node.target)
            elif isinstance(node, (ast.If, ast.While)):
                if taint.expr(node.test):
                    kw = "while" if isinstance(node, ast.While) else "if"
                    findings.append(self.finding(
                        source, node,
                        f"Python `{kw}` on a traced value inside a step "
                        f"builder — use jnp.where / lax.cond (and keep "
                        f"ordered callbacks out of cond branches)"))
            elif isinstance(node, ast.Call):
                name = dotted_name(node.func) or ""
                if name in _COERCIONS and node.args \
                        and any(taint.expr(a) for a in node.args):
                    findings.append(self.finding(
                        source, node,
                        f"`{name}()` coercion of a traced value inside "
                        f"a step builder — concretizing a tracer "
                        f"crashes under jit; keep it an array"))
                elif isinstance(node.func, ast.Attribute) \
                        and node.func.attr in ("item", "tolist") \
                        and not node.args \
                        and taint.expr(node.func.value):
                    findings.append(self.finding(
                        source, node,
                        f"`.{node.func.attr}()` on a traced value inside "
                        f"a step builder — host coercions belong outside "
                        f"the traced step"))
        return findings


# ---------------------------------------------------------------------------
# MGD005 — lock discipline for shared host-side state
# ---------------------------------------------------------------------------

_SHARED_STATE_HINTS = ("fault_log", "health")


@register
class LockDiscipline(Rule):
    """Backend worker code mutates shared host-side state only under the
    owning lock or through the reply-shipping path: read-modify-write on
    a lock-owning object's attributes must sit inside ``with ...lock``,
    ``FaultLog`` internals are never touched directly (its methods are
    internally locked), and health registries are mutated host-side
    only — workers ship events back in replies (PR 7)."""

    code = "MGD005"
    title = "lock discipline"
    rationale = (
        "Backends run per-chip worker threads/processes; an unlocked "
        "`self._busy += x` or a direct poke at FaultLog.events / "
        "ChipHealth fields from worker code races the supervisor and "
        "corrupts telemetry (or worse, quarantine decisions). "
        "FarmHealth stays host-side; process workers ship FaultLog "
        "events back with each reply.")
    fixture_path = "src/repro/hardware/backend/fixture_mod.py"
    fixture_bad = """\
import threading


class B:
    def __init__(self):
        self._lock = threading.Lock()
        self._busy = 0.0

    def _account(self, busy):
        self._busy += busy  # racy read-modify-write outside the lock
"""
    fixture_good = """\
import threading


class B:
    def __init__(self):
        self._lock = threading.Lock()
        self._busy = 0.0

    def _account(self, busy):
        with self._lock:
            self._busy += busy
"""

    def applies(self, rel: str) -> bool:
        return rel.startswith(HARDWARE + "backend/")

    def check(self, source: SourceFile) -> List[Finding]:
        findings = []
        lock_classes = self._lock_owning_classes(source)
        for node in ast.walk(source.tree):
            if isinstance(node, ast.AugAssign) and isinstance(
                    node.target, ast.Attribute):
                base = dotted_name(node.target.value)
                if base == "self":
                    cls = self._enclosing_class(source, node)
                    fn = source.enclosing_function(node)
                    if (cls in lock_classes and fn is not None
                            and fn.name != "__init__"
                            and not self._under_lock(source, node)):
                        findings.append(self.finding(
                            source, node,
                            f"read-modify-write of `self."
                            f"{node.target.attr}` outside `with "
                            f"{lock_classes[cls]}:` in a lock-owning "
                            f"backend class — worker threads race this"))
                elif base is not None and any(
                        h in base.lower() for h in _SHARED_STATE_HINTS):
                    findings.append(self.finding(
                        source, node,
                        f"direct mutation of shared host-side state "
                        f"`{base}.{node.target.attr}` from backend code "
                        f"— health/fault bookkeeping belongs to the "
                        f"farm supervisor (ship events back in replies)"))
            elif isinstance(node, ast.Assign):
                for t in node.targets:
                    if isinstance(t, ast.Attribute):
                        base = dotted_name(t.value)
                        if base is not None and base != "self" and any(
                                h in base.lower()
                                for h in _SHARED_STATE_HINTS):
                            findings.append(self.finding(
                                source, node,
                                f"direct write to shared host-side state "
                                f"`{base}.{t.attr}` from backend code — "
                                f"use the locked API (record/extend) or "
                                f"the reply-shipping path"))
            elif isinstance(node, ast.Call) and isinstance(
                    node.func, ast.Attribute):
                chain = dotted_name(node.func) or ""
                if ".events." in chain and any(
                        h in chain.lower() for h in _SHARED_STATE_HINTS):
                    findings.append(self.finding(
                        source, node,
                        f"`{chain}(...)` bypasses FaultLog's lock — use "
                        f"log.record()/log.extend()/log.drain()"))
        return findings

    @staticmethod
    def _lock_owning_classes(source: SourceFile) -> dict:
        """ClassDef -> the self attribute holding its lock (e.g.
        ``self._lock``), for classes assigning a threading Lock/RLock."""
        owners = {}
        for cls in ast.walk(source.tree):
            if not isinstance(cls, ast.ClassDef):
                continue
            for node in ast.walk(cls):
                if isinstance(node, ast.Assign) and isinstance(
                        node.value, ast.Call):
                    resolved = source.resolve(node.value.func) or ""
                    if resolved.rsplit(".", 1)[-1] in ("Lock", "RLock"):
                        for t in node.targets:
                            name = dotted_name(t)
                            if name and name.startswith("self."):
                                owners[cls] = name
        return owners

    @staticmethod
    def _enclosing_class(source: SourceFile,
                         node: ast.AST) -> Optional[ast.ClassDef]:
        for anc in source.ancestors(node):
            if isinstance(anc, ast.ClassDef):
                return anc
        return None

    @staticmethod
    def _under_lock(source: SourceFile, node: ast.AST) -> bool:
        for anc in source.ancestors(node):
            if isinstance(anc, ast.With):
                for item in anc.items:
                    name = dotted_name(item.context_expr) or ""
                    if "lock" in name.lower():
                        return True
        return False


# ---------------------------------------------------------------------------
# MGD006 — fence before state-dependent boundaries
# ---------------------------------------------------------------------------

_SYNC_ATTR_CALLS = {"save"}            # ckpt.save / checkpoint.save
_SYNC_MODULE_HINTS = ("ckpt", "checkpoint")
_SYNC_NAME_CALLS = {"_recalibrate", "eval_fn"}
# serving-tier parameter swap: <store>.publish(...) installs the trainer's
# tree as the serving snapshot — publishing with plant writes still in
# flight would serve a tree the device never held (PR 10)
_SWAP_ATTR_CALLS = {"publish"}
_SWAP_MODULE_HINTS = ("store",)


@register
class FenceBeforeSync(Rule):
    """In plant-driving code (any function that binds a plant
    ``fence``), every checkpoint save / recalibration / eval callsite —
    and every serving-tier parameter swap (``<store>.publish``) — must
    have a ``fence()`` call among its preceding statements: a
    double-buffered farm leaves parameter writes in flight between
    steps, and a state-dependent boundary that runs with writes pending
    breaks bit-exact resume (PR 7) or publishes a parameter tree the
    device never held (PR 10)."""

    code = "MGD006"
    title = "fence before checkpoint/recal/eval/param-swap"
    rationale = (
        "ChipFarm(pipeline=True) overlaps step N+1's writes with step "
        "N's compute; checkpoints, evals, recalibration and serving "
        "parameter swaps read or rewrite device state and must not "
        "race an in-flight write. train_mgd and OnlineTrimmer fence "
        "first — every new boundary callsite must too.")
    fixture_path = "src/repro/training/fixture_mod.py"
    fixture_bad = """\
from . import checkpoint as ckpt


def train(plant, params, state, done):
    fence = getattr(plant, "fence", lambda: None)
    ckpt.save("dir", done, {"params": params, "state": state})
    return params
"""
    fixture_good = """\
from . import checkpoint as ckpt


def train(plant, params, state, done):
    fence = getattr(plant, "fence", lambda: None)
    fence()
    ckpt.save("dir", done, {"params": params, "state": state})
    return params
"""

    def applies(self, rel: str) -> bool:
        return (rel.startswith("src/repro/")
                and not rel.startswith(HARDWARE))

    def check(self, source: SourceFile) -> List[Finding]:
        findings = []
        for fn in ast.walk(source.tree):
            if not isinstance(fn, ast.FunctionDef):
                continue
            if not self._binds_fence(fn):
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                label = self._sync_label(node)
                if label is None:
                    continue
                if not self._fence_precedes(source, node, fn):
                    findings.append(self.finding(
                        source, node,
                        f"{label} without a preceding fence() — a "
                        f"pipelined farm may still have parameter "
                        f"writes in flight; drain them first"))
        return findings

    @staticmethod
    def _binds_fence(fn: ast.FunctionDef) -> bool:
        for node in ast.walk(fn):
            if isinstance(node, ast.Name) and node.id == "fence":
                return True
            if isinstance(node, ast.Attribute) and node.attr == "fence":
                return True
            if isinstance(node, ast.Constant) and node.value == "fence":
                return True
        return False

    @staticmethod
    def _sync_label(call: ast.Call) -> Optional[str]:
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _SYNC_ATTR_CALLS:
            base = (dotted_name(call.func.value) or "").lower()
            if any(h in base for h in _SYNC_MODULE_HINTS):
                return f"checkpoint save `{dotted_name(call.func)}`"
            return None
        if isinstance(call.func, ast.Attribute) \
                and call.func.attr in _SWAP_ATTR_CALLS:
            base = (dotted_name(call.func.value) or "").lower()
            if any(h in base for h in _SWAP_MODULE_HINTS):
                return f"parameter swap `{dotted_name(call.func)}`"
            return None
        name = dotted_name(call.func)
        if name in _SYNC_NAME_CALLS:
            kind = ("recalibration" if name == "_recalibrate"
                    else "evaluation")
            return f"{kind} call `{name}()`"
        return None

    @staticmethod
    def _is_fence_call(node: ast.AST) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Call):
                name = dotted_name(sub.func) or ""
                if name == "fence" or name.endswith(".fence"):
                    return True
        return False

    def _fence_precedes(self, source: SourceFile, call: ast.Call,
                        fn: ast.FunctionDef) -> bool:
        """A fence() call appears in a statement preceding the sync
        call's statement, in its block or any enclosing block up to the
        function body."""
        stmt: ast.AST = call
        for anc in source.ancestors(call):
            for field in ("body", "orelse", "finalbody"):
                block = getattr(anc, field, None)
                if isinstance(block, list) and stmt in block:
                    for prev in block[:block.index(stmt)]:
                        if self._is_fence_call(prev):
                            return True
            stmt = anc
            if anc is fn:
                break
        return False
