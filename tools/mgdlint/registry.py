"""Rule registry + the lint engine.

A rule is a class with a ``code`` (MGDxxx), a path scope
(``applies(rel)``) and a ``check(source) -> [Finding]``.  Rules register
themselves via the ``@register`` decorator at import time
(``rules.py``); the engine parses each file once and hands the shared
``SourceFile`` to every applicable rule.

Waivers are applied here, not in rules: a rule always reports what it
sees, and the engine drops findings covered by a well-formed inline
waiver — so ``--no-waivers`` style auditing stays possible and waiver
bookkeeping (malformed waivers become MGD000 findings) lives in one
place.
"""
from __future__ import annotations

import dataclasses
import pathlib
from typing import Dict, List, Optional, Sequence, Tuple, Type

from .walker import SourceFile, iter_python_files

#: Pseudo-code for waiver-syntax problems (not a registrable rule:
#: a malformed waiver can never be waived).
WAIVER_CODE = "MGD000"


@dataclasses.dataclass(frozen=True)
class Finding:
    """One rule violation at one site."""

    code: str
    path: str                   # POSIX path relative to the lint root
    line: int
    col: int
    message: str
    symbol: str                 # enclosing qualname — baseline anchor
    snippet: str                # stripped source line — baseline anchor

    def fingerprint(self) -> Tuple[str, str, str, str]:
        """Line-number-free identity used for baseline matching, so
        unrelated edits above a grandfathered finding don't churn the
        baseline file."""
        return (self.code, self.path, self.symbol, self.snippet)

    def format(self) -> str:
        sym = f" [{self.symbol}]" if self.symbol != "<module>" else ""
        return (f"{self.path}:{self.line}:{self.col}: {self.code} "
                f"{self.message}{sym}")


class Rule:
    """Base class: subclasses set ``code``/``title``/``rationale`` and
    implement ``applies``/``check``.  ``fixture_path``/``fixture_bad``/
    ``fixture_good`` drive both ``--self-test`` and the pytest fixture
    suite — every rule must prove it fires and that clean code passes."""

    code: str = ""
    title: str = ""
    rationale: str = ""
    fixture_path: str = ""      # where the fixture lives under a fake root
    fixture_bad: str = ""       # snippet the rule MUST flag
    fixture_good: str = ""      # snippet the rule MUST pass

    def applies(self, rel: str) -> bool:
        raise NotImplementedError

    def check(self, source: SourceFile) -> List[Finding]:
        raise NotImplementedError

    # -- helpers for subclasses ---------------------------------------------

    def finding(self, source: SourceFile, node, message: str) -> Finding:
        line = getattr(node, "lineno", 0)
        return Finding(code=self.code, path=source.rel, line=line,
                       col=getattr(node, "col_offset", 0) + 1,
                       message=message, symbol=source.qualname(node),
                       snippet=source.snippet(line))


RULES: Dict[str, Type[Rule]] = {}


def register(cls: Type[Rule]) -> Type[Rule]:
    if not cls.code or cls.code in RULES:
        raise ValueError(f"bad or duplicate rule code {cls.code!r}")
    RULES[cls.code] = cls
    return cls


def all_rules(select: Optional[Sequence[str]] = None) -> List[Rule]:
    codes = sorted(RULES) if not select else list(select)
    unknown = [c for c in codes if c not in RULES]
    if unknown:
        raise ValueError(f"unknown rule code(s): {', '.join(unknown)} — "
                         f"registered: {', '.join(sorted(RULES))}")
    return [RULES[c]() for c in codes]


@dataclasses.dataclass
class LintResult:
    findings: List[Finding]             # violations after waivers
    waived: List[Finding]               # suppressed by inline waivers
    files_checked: int
    parse_errors: List[str]


def run_lint(paths: Sequence[pathlib.Path], root: pathlib.Path,
             select: Optional[Sequence[str]] = None) -> LintResult:
    """Parse every file once, run each applicable rule, apply waivers,
    and report malformed waivers as MGD000 findings."""
    rules = all_rules(select)
    findings: List[Finding] = []
    waived: List[Finding] = []
    parse_errors: List[str] = []
    n_files = 0
    for path in iter_python_files(paths, root):
        try:
            source = SourceFile(path, root)
        except (SyntaxError, UnicodeDecodeError) as e:
            parse_errors.append(f"{path}: {e}")
            continue
        n_files += 1
        for rule in rules:
            if not rule.applies(source.rel):
                continue
            for f in rule.check(source):
                if source.waived(f.code, f.line):
                    waived.append(f)
                else:
                    findings.append(f)
        for w in source.waivers:
            why = w.malformed
            if why:
                findings.append(Finding(
                    code=WAIVER_CODE, path=source.rel, line=w.line, col=1,
                    message=f"malformed waiver ({why}): {w.raw}",
                    symbol="<module>", snippet=source.snippet(w.line)))
    findings.sort(key=lambda f: (f.path, f.line, f.code))
    return LintResult(findings=findings, waived=waived,
                      files_checked=n_files, parse_errors=parse_errors)
