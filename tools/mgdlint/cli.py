"""mgdlint command line.

Exit codes: 0 clean (or all findings grandfathered/waived), 1 new
findings (or parse errors, or stale baseline entries under --strict),
2 usage error.  ``--self-test`` seeds one violation per rule under a
temp tree and proves each rule fires, each good fixture passes, waivers
suppress, and the baseline round-trips — the same never-trust-a-silent-
gate pattern as ``benchmarks/check_regression.py --self-test``.
"""
from __future__ import annotations

import argparse
import pathlib
import sys
import tempfile
from typing import List, Optional

from . import baseline as baseline_mod
from .registry import all_rules, run_lint

DEFAULT_BASELINE = "tools/mgdlint/baseline.json"


def _parser() -> argparse.ArgumentParser:
    p = argparse.ArgumentParser(
        prog="mgdlint",
        description="AST invariant checker for the MGD repro repo "
                    "(determinism, host-boundary purity, timeout/lock/"
                    "fence discipline).")
    p.add_argument("paths", nargs="*", default=[],
                   help="files or directories to lint (default: src "
                        "tests benchmarks, whichever exist)")
    p.add_argument("--root", type=pathlib.Path, default=None,
                   help="repo root paths are resolved against "
                        "(default: cwd)")
    p.add_argument("--baseline", type=pathlib.Path, default=None,
                   help=f"baseline file (default: {DEFAULT_BASELINE} "
                        f"under --root when present)")
    p.add_argument("--write-baseline", action="store_true",
                   help="rewrite the baseline to grandfather every "
                        "current finding, then exit 0")
    p.add_argument("--select", default=None,
                   help="comma-separated rule codes to run "
                        "(default: all)")
    p.add_argument("--list-rules", action="store_true",
                   help="print the rule catalogue and exit")
    p.add_argument("--strict", action="store_true",
                   help="also fail on stale baseline entries")
    p.add_argument("--self-test", action="store_true",
                   help="verify every rule fires on its bad fixture, "
                        "passes its good fixture, and that waivers + "
                        "baseline suppress correctly")
    p.add_argument("-q", "--quiet", action="store_true",
                   help="only print failures")
    return p


def _list_rules() -> int:
    for rule in all_rules():
        print(f"{rule.code}  {rule.title}")
        print(f"       {rule.rationale}")
    return 0


def self_test(verbose: bool = True) -> int:
    """Seed one violation per rule in a temp tree; every rule must fire
    on its bad fixture, pass its good one, honour a waiver, and be
    suppressed by a written baseline.  Returns 0 on success."""
    failures: List[str] = []

    def check(ok: bool, what: str) -> None:
        if ok:
            if verbose:
                print(f"  ok  {what}")
        else:
            failures.append(what)
            print(f"FAIL  {what}", file=sys.stderr)

    for rule in all_rules():
        with tempfile.TemporaryDirectory(prefix="mgdlint-st-") as tmp:
            root = pathlib.Path(tmp)
            target = root / rule.fixture_path
            target.parent.mkdir(parents=True, exist_ok=True)

            target.write_text(rule.fixture_bad)
            res = run_lint([target], root, select=[rule.code])
            fired = [f for f in res.findings if f.code == rule.code]
            check(bool(fired),
                  f"{rule.code} fires on its seeded violation")

            target.write_text(rule.fixture_good)
            res = run_lint([target], root, select=[rule.code])
            check(not res.findings and not res.parse_errors,
                  f"{rule.code} passes its good fixture")

            if fired:
                lines = rule.fixture_bad.splitlines(keepends=True)
                for idx in sorted({f.line - 1 for f in fired}):
                    lines[idx] = (lines[idx].rstrip("\n")
                                  + f"  # mgdlint: disable={rule.code} "
                                    f"(self-test waiver)\n")
                target.write_text("".join(lines))
                res = run_lint([target], root, select=[rule.code])
                check(not any(f.code == rule.code for f in res.findings)
                      and len(res.waived) >= 1,
                      f"{rule.code} waiver suppresses the finding")

                target.write_text(rule.fixture_bad)
                res = run_lint([target], root, select=[rule.code])
                bl = root / "baseline.json"
                baseline_mod.save(bl, res.findings)
                entries = baseline_mod.load(bl)
                new, grandfathered, stale = baseline_mod.split(
                    run_lint([target], root,
                             select=[rule.code]).findings, entries)
                check(not new and grandfathered and not stale,
                      f"{rule.code} baseline round-trip grandfathers it")

    # Malformed waiver (missing reason) must surface as MGD000.
    with tempfile.TemporaryDirectory(prefix="mgdlint-st-") as tmp:
        root = pathlib.Path(tmp)
        bad = root / "src" / "repro" / "core" / "m.py"
        bad.parent.mkdir(parents=True)
        bad.write_text("import numpy as np\n"
                       "x = np.random.rand(3)"
                       "  # mgdlint: disable=MGD002\n")
        res = run_lint([bad], root)
        check(any(f.code == "MGD000" for f in res.findings),
              "MGD000 reports a reason-less waiver")

    if failures:
        print(f"mgdlint --self-test: {len(failures)} check(s) FAILED",
              file=sys.stderr)
        return 1
    if verbose:
        print(f"mgdlint --self-test: all rules fire, pass, waive and "
              f"baseline correctly ({len(all_rules())} rules)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    args = _parser().parse_args(argv)
    if args.list_rules:
        return _list_rules()
    if args.self_test:
        return self_test(verbose=not args.quiet)

    root = (args.root or pathlib.Path.cwd()).resolve()
    paths = [pathlib.Path(p) for p in args.paths]
    if not paths:
        paths = [root / d for d in ("src", "tests", "benchmarks")
                 if (root / d).is_dir()]
        if not paths:
            print("mgdlint: no paths given and no default directories "
                  "found", file=sys.stderr)
            return 2

    select = None
    if args.select:
        select = [c.strip() for c in args.select.split(",") if c.strip()]

    try:
        result = run_lint(paths, root, select=select)
    except (FileNotFoundError, ValueError) as e:
        print(f"mgdlint: {e}", file=sys.stderr)
        return 2

    baseline_path = args.baseline or (root / DEFAULT_BASELINE)
    if args.write_baseline:
        entries = baseline_mod.save(baseline_path, result.findings)
        print(f"mgdlint: wrote {len(entries)} baseline entr"
              f"{'y' if len(entries) == 1 else 'ies'} to "
              f"{baseline_path}")
        return 0

    try:
        entries = baseline_mod.load(baseline_path)
    except ValueError as e:
        print(f"mgdlint: {e}", file=sys.stderr)
        return 2
    new, grandfathered, stale = baseline_mod.split(result.findings,
                                                   entries)

    for err in result.parse_errors:
        print(f"mgdlint: parse error: {err}", file=sys.stderr)
    for f in new:
        print(f.format())

    failed = bool(new or result.parse_errors
                  or (args.strict and stale))
    if not args.quiet or failed:
        bits = [f"{result.files_checked} files",
                f"{len(new)} new finding(s)"]
        if grandfathered:
            bits.append(f"{len(grandfathered)} grandfathered")
        if result.waived:
            bits.append(f"{len(result.waived)} waived")
        if stale:
            bits.append(f"{len(stale)} stale baseline entr"
                        f"{'y' if len(stale) == 1 else 'ies'}")
        print(f"mgdlint: {', '.join(bits)}")
    if stale and args.strict:
        for e in stale:
            print(f"mgdlint: stale baseline entry: {e['rule']} "
                  f"{e['path']} [{e['symbol']}]", file=sys.stderr)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
