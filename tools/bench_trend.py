"""Trend tracking over accumulated nightly benchmark artifacts.

``check_regression`` gates one fresh run against one committed baseline —
it cannot see SLOW drift, where every nightly step stays inside its
tolerance band but the sum walks out of it over weeks.  This tool reads a
history directory of nightly artifact sets and reports, per (bench, name)
metric, the value trajectory over time, flagging any metric whose change
across the trailing window exceeds the same ``check_regression``
tolerance band that gates single runs (band anchored at the window's
first value).

History layout (what the nightly workflow's cache step accumulates):

    history/
      2026-08-08_412/   farm_scaling.json  scaling_laws.json  ...
      2026-08-09_413/   farm_scaling.json  ...

one subdirectory per nightly run, lexically sorted = chronological when
named ``<date>_<run>``.  Flat ``*.json`` files directly in the history
dir are treated as a single entry (handy for ad-hoc local use).

Informational by default (exit 0 even with drift, like the nightly
regression report); ``--strict`` exits non-zero on any flagged metric.

    python tools/bench_trend.py --history artifacts/bench-history \
        --window 14 --out artifacts/bench-trend.csv
"""
from __future__ import annotations

import argparse
import json
import os
import pathlib
import sys

# runnable from any CWD: benchmarks/ lives next to tools/
_REPO = pathlib.Path(__file__).resolve().parent.parent
if str(_REPO) not in sys.path:
    sys.path.insert(0, str(_REPO))

from benchmarks.check_regression import _band, spec_for  # noqa: E402


def load_history(history_dir):
    """[(label, {bench: {name: value}})], chronological (lexical label
    order).  Bad/empty JSON files are skipped with a warning — a corrupt
    artifact must not kill the whole report."""
    root = pathlib.Path(history_dir)
    entries = []
    subdirs = sorted(p for p in root.iterdir() if p.is_dir())
    flat = sorted(root.glob("*.json"))
    groups = ([(p.name, sorted(p.glob("*.json"))) for p in subdirs]
              + ([(root.name, flat)] if flat else []))
    for label, files in groups:
        metrics = {}
        for f in files:
            try:
                with open(f) as fh:
                    rows = json.load(fh)["rows"]
            except (json.JSONDecodeError, KeyError, OSError) as e:
                print(f"bench_trend: skipping {f}: {e!r}", file=sys.stderr)
                continue
            bench = f.stem
            metrics.setdefault(bench, {})
            for r in rows:
                metrics[bench][r["name"]] = float(r["value"])
        if metrics:
            entries.append((label, metrics))
    return entries


def trend_report(entries, window: int):
    """(csv_lines, flagged): one line per (bench, name) present in the
    latest entry, with the trailing-window drift verdict."""
    lines = ["bench,name,points,window_first,latest,delta,status"]
    flagged = []
    if not entries:
        return lines, flagged
    latest_label, latest = entries[-1]
    for bench in sorted(latest):
        for name in sorted(latest[bench]):
            series = [(label, m[bench][name]) for label, m in entries
                      if bench in m and name in m[bench]]
            tail = series[-max(2, window):]
            first, last = tail[0][1], tail[-1][1]
            spec = spec_for(bench, name)
            if spec is None:
                status = "info"          # ungated metric, reported only
            elif len(tail) < 2:
                status = "new"
            else:
                lo, hi = _band(spec, first)
                status = "ok" if lo <= last <= hi else "DRIFT"
            if status == "DRIFT":
                flagged.append(
                    (bench, name, first, last, tail[0][0], tail[-1][0]))
            lines.append(f"{bench},{name},{len(series)},{first:.6g},"
                         f"{last:.6g},{last - first:.6g},{status}")
    return lines, flagged


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--history", required=True,
                    help="directory of per-run artifact subdirectories")
    ap.add_argument("--window", type=int, default=14,
                    help="trailing entries the drift check spans")
    ap.add_argument("--out", default=None,
                    help="write the CSV report here (default: stdout only)")
    ap.add_argument("--strict", action="store_true",
                    help="exit non-zero when any metric drifted")
    args = ap.parse_args(argv)

    entries = load_history(args.history)
    lines, flagged = trend_report(entries, args.window)
    report = "\n".join(lines) + "\n"
    print(report, end="")
    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            f.write(report)
    print(f"bench_trend: {len(entries)} runs, "
          f"{len(flagged)} metrics drifted beyond tolerance over the "
          f"trailing {args.window}", file=sys.stderr)
    for bench, name, first, last, l0, l1 in flagged:
        print(f"  DRIFT {bench}:{name}  {first:.6g} ({l0}) -> "
              f"{last:.6g} ({l1})", file=sys.stderr)
    return 1 if (args.strict and flagged) else 0


if __name__ == "__main__":
    raise SystemExit(main())
